"""Unified kernel benchmark driver: sweep, validate, record.

Runs the ``repro.bench`` autotuner over every registered kernel family and
emits ``BENCH_kernels.json`` — per (kernel, shape, dtype): the best
validated :class:`BlockConfig`, median us/call, analytic GFLOP/s, and the
analytic HBM traffic at that config (the Table-III 'memory access'
analogue, via :func:`repro.core.apr.reduction_hbm_traffic`).  The JSON
schema is documented in ``benchmarks/README.md``.

Usage::

    python benchmarks/bench_kernels.py --quick            # tiny shapes, CI
    python benchmarks/bench_kernels.py                    # full suite
    python benchmarks/bench_kernels.py --out /tmp/b.json --cache /tmp/tc.json

Off-TPU the kernels run in Pallas interpret mode, so absolute times are a
correctness-path proxy (the ``backend`` field records this); on TPU the
same command produces real device numbers.  Tuned winners also land in the
shared config cache, so every later ``repro.kernels`` call site picks them
up automatically.
"""
import argparse
import datetime
import json
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
if str(_REPO / "src") not in sys.path:
    sys.path.insert(0, str(_REPO / "src"))

SCHEMA_VERSION = 1

# Per-family benchmark shapes.  quick: small enough for interpret-mode CI;
# full: LM-layer-sized geometries (run these on real hardware).
SUITES = {
    "quick": {
        "apr_matmul": [{"m": 64, "k": 128, "n": 64}],
        "apr_matmul_fused": [{"m": 64, "k": 128, "n": 64}],
        "quant_matmul": [{"m": 64, "k": 128, "n": 64}],
        "quant_matmul_fused": [{"m": 64, "k": 128, "n": 64}],
        "apr_conv": [{"b": 1, "h": 8, "w": 8, "c": 4, "hf": 3, "wf": 3,
                      "m": 8, "stride": 1, "padding": 1}],
        "apr_conv_fused": [{"b": 1, "h": 8, "w": 8, "c": 4, "hf": 3, "wf": 3,
                            "m": 8, "stride": 1, "padding": 1}],
        "flash_decode": [{"b": 2, "hq": 4, "hkv": 2, "d": 32, "s": 128}],
        "flash_decode_paged": [{"b": 2, "hq": 4, "hkv": 2, "d": 32,
                                "pages": 4, "ps": 32},
                               {"b": 2, "hq": 4, "hkv": 2, "d": 32,
                                "pages": 4, "ps": 32, "kv_int8": 1}],
        "mamba2": [{"b": 1, "t": 32, "h": 2, "p": 8, "n": 8}],
        "rwkv6": [{"b": 1, "t": 32, "h": 2, "d": 8}],
    },
    "full": {
        "apr_matmul": [
            {"m": 256, "k": 512, "n": 256},
            {"m": 512, "k": 2048, "n": 512},
        ],
        "apr_matmul_fused": [
            {"m": 256, "k": 512, "n": 256},
            {"m": 512, "k": 2048, "n": 512},
        ],
        "quant_matmul": [
            {"m": 256, "k": 512, "n": 256},
            {"m": 512, "k": 2048, "n": 512},
        ],
        "quant_matmul_fused": [
            {"m": 256, "k": 512, "n": 256},
        ],
        "apr_conv": [
            # LeNet conv2-sized im2col (the paper's benchmark operator)
            {"b": 4, "h": 14, "w": 14, "c": 6, "hf": 5, "wf": 5,
             "m": 16, "stride": 1, "padding": 0},
        ],
        "apr_conv_fused": [
            {"b": 4, "h": 14, "w": 14, "c": 6, "hf": 5, "wf": 5,
             "m": 16, "stride": 1, "padding": 0},
        ],
        "flash_decode": [
            {"b": 4, "hq": 8, "hkv": 4, "d": 64, "s": 1024},
        ],
        "flash_decode_paged": [
            {"b": 4, "hq": 8, "hkv": 4, "d": 64, "pages": 8, "ps": 128},
            {"b": 4, "hq": 8, "hkv": 4, "d": 64, "pages": 8, "ps": 128,
             "kv_int8": 1},
        ],
        "mamba2": [
            {"b": 2, "t": 256, "h": 4, "p": 32, "n": 16},
        ],
        "rwkv6": [
            {"b": 2, "t": 256, "h": 4, "d": 32},
        ],
    },
}


def bench_all(*, quick: bool = False, dtype: str = "float32",
              cache_path=None, iters: int = 3, warmup: int = 1,
              max_candidates=None):
    import jax

    from repro.bench import ConfigCache, all_specs, autotune, default_cache

    cache = ConfigCache(cache_path) if cache_path else default_cache()
    suite = SUITES["quick" if quick else "full"]
    if quick and max_candidates is None:
        max_candidates = 4

    report = {
        "schema_version": SCHEMA_VERSION,
        "generated_at": datetime.datetime.now().isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "mode": "quick" if quick else "full",
        "dtype": dtype,
        "kernels": {},
    }
    for name, spec in sorted(all_specs().items()):
        entries = []
        for shape in suite.get(name, []):
            res = autotune(spec, shape, dtype=dtype, cache=cache,
                           iters=iters, warmup=warmup,
                           max_candidates=max_candidates)
            entries.append({
                "shape": dict(shape),
                "shape_key": res.shape_key,
                "dtype": res.dtype,
                "best_config": res.config.to_dict() if res.ok else None,
                "us_per_call": round(res.us, 2) if res.ok else None,
                "gflops": round(res.gflops, 4) if res.ok else None,
                "hbm_bytes_analytic": res.hbm_bytes,
                "n_candidates": res.n_candidates,
                "n_rejected": len(res.rejected),
            })
        report["kernels"][name] = entries
    return report


def run(csv: bool = False, quick: bool = True):
    """benchmarks/run.py integration: quick sweep, CSV row per kernel."""
    report = bench_all(quick=quick)
    rows = []
    for name, entries in sorted(report["kernels"].items()):
        for e in entries:
            if e["best_config"] is None:
                continue
            cfg = "/".join(f"{k}={v}" for k, v in sorted(e["best_config"].items()))
            rows.append(f"bench_kernels.{name}.{e['shape_key']},"
                        f"{e['us_per_call']:.2f},"
                        f"gflops={e['gflops']};cfg={cfg}")
            if not csv:
                print(f"{name:14s} {e['shape_key']:32s} {e['us_per_call']:10.1f}us "
                      f"{e['gflops']:8.3f} GF/s  {cfg}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes + pruned candidate list (CI-sized)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--out", default=str(_REPO / "BENCH_kernels.json"),
                    help="report path (default: repo-root BENCH_kernels.json)")
    ap.add_argument("--cache", default=None,
                    help="tuned-config cache path (default: $REPRO_TUNE_CACHE "
                         "or ~/.cache/repro/tune_cache.json)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--max-candidates", type=int, default=None)
    args = ap.parse_args()

    report = bench_all(quick=args.quick, dtype=args.dtype,
                       cache_path=args.cache, iters=args.iters,
                       max_candidates=args.max_candidates)
    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")

    n = sum(len(v) for v in report["kernels"].values())
    print(f"wrote {out} ({n} entries, backend={report['backend']}, "
          f"mode={report['mode']})")
    for name, entries in sorted(report["kernels"].items()):
        for e in entries:
            status = (f"{e['us_per_call']:.1f}us {e['gflops']:.3f} GF/s "
                      f"cfg={e['best_config']}"
                      if e["best_config"] is not None else "NO VALID CONFIG")
            print(f"  {name:14s} {e['shape_key']:36s} {status}")


if __name__ == "__main__":
    main()
