"""Render the dry-run roofline table (EXPERIMENTS.md §Roofline) from
dryrun_results.json."""
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def load():
    with open(RESULTS) as f:
        return json.load(f)


def fmt_row(v):
    if v.get("status") == "skipped":
        return None
    mem = v.get("memory") or {}
    return (
        f"| {v['arch']} | {v['shape']} | {v['mesh']} | "
        f"{v.get('variant', 'baseline')} | "
        f"{v.get('t_compute_s', 0):.3e} | {v.get('t_memory_s', 0):.3e} | "
        f"{v.get('t_collective_s', 0):.3e} | {v.get('dominant','-'):10s} | "
        f"{(v.get('useful_flops_ratio') or 0):.2f} | "
        f"{(mem.get('peak_bytes') or 0)/2**30:.1f} |"
    )


def run(csv=False):
    rows = []
    try:
        results = load()
    except FileNotFoundError:
        print(f"(no {RESULTS}; run `python -m repro.launch.dryrun --all` first)")
        return rows
    if not csv:
        print("| arch | shape | mesh | variant | t_comp(s) | t_mem(s) | t_coll(s) | "
              "dominant | useful_flops | peak GiB/chip |")
        print("|---|---|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_err = 0
    for key in sorted(results):
        v = results[key]
        if v.get("status") == "ok":
            n_ok += 1
            line = fmt_row(v)
            if not csv and line:
                print(line)
            rows.append(
                f"roofline.{v['arch']}.{v['shape']}.{v['mesh']},"
                f"{v.get('bound_time', v.get('t_compute_s', 0))},"
                f"dominant={v.get('dominant')};useful={v.get('useful_flops_ratio')}"
            )
        elif v.get("status") == "skipped":
            n_skip += 1
        else:
            n_err += 1
            if not csv:
                print(f"| {v['arch']} | {v['shape']} | {v['mesh']} | ERROR: "
                      f"{v.get('error', '?')[:60]} |")
    if not csv:
        print(f"\n{n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    return rows
