"""Kernel-level Table-III analogue: HBM traffic & latency of the APR
residency vs the baseline (partial sums through HBM), plus interpret-mode
us/call of the Pallas kernels on small shapes (CPU correctness-path timing,
not TPU performance)."""
import time

import jax
import jax.numpy as jnp

from repro.core.apr import reduction_hbm_traffic
from repro.kernels.apr_matmul import accumulator_traffic_bytes, apr_matmul
from repro.roofline import hw

# (M, N, K, block_k) matmul reduction geometries: LM-layer sized
GEOMS = [
    ("mlp_up d4096xff14336", 4096, 14336, 4096, 512),
    ("attn_qk 32k decode", 8, 32768, 128, 512),
    ("lenet_conv2 im2col", 1600, 16, 150, 128),
    ("expert_ffn arctic", 2048, 4864, 7168, 512),
]


def run(csv=False):
    rows = []
    if not csv:
        print(f"{'geometry':24s} {'steps':>6s} {'apr bytes':>12s} "
              f"{'hbm bytes':>13s} {'saving':>8s} {'apr us(HBM-bound)':>18s}")
    for name, m, n, k, bk in GEOMS:
        steps = -(-k // bk)
        apr = accumulator_traffic_bytes(m, n, k, bk, "apr")
        hbm = accumulator_traffic_bytes(m, n, k, bk, "hbm")
        saving = 1 - apr / hbm
        # accumulator-traffic time at HBM bandwidth (the paper's 'memory
        # access' column, converted to seconds on the target part)
        t_apr = apr / hw.HBM_BW * 1e6
        if not csv:
            print(f"{name:24s} {steps:6d} {apr:12,} {hbm:13,} "
                  f"{100*saving:7.1f}% {t_apr:12.2f}us")
        rows.append(f"kernel_traffic.{name.split()[0]},{t_apr:.2f},"
                    f"saving_pct={100*saving:.1f}")

    # interpret-mode timing of the real kernel (correctness path)
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    for residency in ("apr", "hbm"):
        out = apr_matmul(x, y, residency=residency)
        out.block_until_ready()
        t0 = time.time()
        for _ in range(3):
            apr_matmul(x, y, residency=residency).block_until_ready()
        us = (time.time() - t0) / 3 * 1e6
        rows.append(f"apr_matmul.interpret.{residency},{us:.0f},256x512x256")
        if not csv:
            print(f"apr_matmul 256x512x256 interpret residency={residency}: "
                  f"{us:,.0f} us/call")
    return rows
