"""Tensor-parallel serving benchmark: 1 device vs an N-way device mesh.

Drives the same shared-system-prompt trace through the paged engine twice —

* ``single`` — 1-device :class:`PagedServeEngine` (the matrix reference),
* ``mesh``   — the same engine over an N-way tensor-parallel mesh
  (`repro.parallel.tp`): attention heads, MLP blocks and the KV page
  pools sharded over N devices, block tables / allocator / prefix cache
  staying host-side and single-source

— and writes ``BENCH_parallel.json`` (schema in benchmarks/README.md).
The headline numbers are the per-device footprint reductions: the KV page
pool and the weights each device holds must shrink ~Nx versus the logical
single-device arrays, while the emitted greedy tokens stay identical
token for token (the repo-wide acceptance invariant — sharding must be
invisible in the outputs, see docs/parallel.md for why the split-K
contraction makes that bitwise).

Gates (exit 1 on violation):

* greedy tokens identical between the 1-device and mesh engines,
* per-device KV-pool bytes reduced >= 3x at mesh=4 (KV heads shard
  exactly Nx when ``num_kv_heads % N == 0``),
* per-device weight bytes reduced >= 2x (embeddings stay replicated, so
  the weight reduction is sublinear at smoke scale).

On a CPU-only machine the N devices are simulated
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``, injected before
jax loads — same mechanism as ``repro.launch.serve --mesh N``).

    PYTHONPATH=src python benchmarks/bench_parallel.py --quick
"""
import argparse
import datetime
import json
import os
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
for _p in (str(_REPO / "src"), str(_REPO / "benchmarks")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

SCHEMA_VERSION = 1

MIN_KV_REDUCTION = 3.0
MIN_WEIGHT_REDUCTION = 2.0


def _ensure_devices(n: int) -> None:
    """Must run before jax initialises its backends."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    m = eng.run_until_drained()
    assert all(r.done for r in reqs)
    return [r.output for r in reqs], m


def bench(*, mesh_n, arch, requests, max_new, slots, page_size,
          prefill_chunk, kv_dtype):
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel import (ParallelContext, make_serving_mesh,
                                make_tp_context)
    from repro.serve import PagedServeEngine, Request

    cfg = get_config(arch, smoke=True)
    if cfg.num_heads % mesh_n or cfg.num_kv_heads % mesh_n \
            or cfg.d_ff % mesh_n:
        # lift the smoke geometry to a TP-divisible head layout, same as
        # repro.launch.serve --mesh (full-size configs divide naturally)
        up = lambda v, n: -(-v // n) * n
        hkv = up(cfg.num_kv_heads, mesh_n)
        cfg = dataclasses.replace(
            cfg, num_kv_heads=hkv,
            num_heads=up(max(cfg.num_heads, hkv), hkv),
            head_dim=cfg.resolved_head_dim, d_ff=up(cfg.d_ff, mesh_n))
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))

    head = [2 + (j % 5) for j in range(2 * page_size)]
    trace = lambda: [Request(rid=i, prompt=head + [50 + i] * 4,
                             max_new_tokens=max_new)
                     for i in range(requests)]
    kw = dict(slots=slots, page_size=page_size, prefill_chunk=prefill_chunk,
              kv_dtype=kv_dtype)

    single = PagedServeEngine(bundle, params, ParallelContext(None), **kw)
    out_1, m_1 = _drain(single, trace())
    kv_bytes_1 = single.kv_pool_bytes()
    w_bytes_1 = sum(a.nbytes for a in jax.tree.leaves(single.params)
                    if hasattr(a, "nbytes"))

    pctx = make_tp_context(make_serving_mesh(mesh_n))
    sharded = PagedServeEngine(bundle, params, pctx, **kw)
    out_n, m_n = _drain(sharded, trace())

    kv_dev = sharded.kv_pool_bytes_per_device()
    w_dev = sharded.weight_bytes_per_device()
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_at": datetime.datetime.now().isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "arch": arch,
        "mesh": mesh_n,
        "devices": [str(d) for d in pctx.mesh.devices.flat],
        "geometry": {"num_heads": cfg.num_heads,
                     "num_kv_heads": cfg.num_kv_heads,
                     "d_ff": cfg.d_ff, "d_model": cfg.d_model},
        "workload": {"requests": requests, "prompt_len": len(head) + 4,
                     "max_new": max_new, "slots": slots,
                     "page_size": page_size, "prefill_chunk": prefill_chunk,
                     "kv_dtype": kv_dtype},
        "single": {"kv_pool_bytes": kv_bytes_1, "weight_bytes": w_bytes_1,
                   "decode_tps": round(m_1.decode_tps, 2)},
        "mesh_engine": {"kv_pool_bytes_per_device": kv_dev,
                        "weight_bytes_per_device": w_dev,
                        "tp_degree": sharded.tp_plan.degree,
                        "kv_sharded": sharded.tp_plan.shard_kv,
                        "decode_tps": round(m_n.decode_tps, 2)},
        "kv_bytes_reduction": round(kv_bytes_1 / max(kv_dev, 1), 3),
        "weight_bytes_reduction": round(w_bytes_1 / max(w_dev, 1), 3),
        "outputs_identical": out_1 == out_n,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized trace (fewer/shorter requests)")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--mesh", type=int, default=4)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--kv-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16")
    ap.add_argument("--out", default=str(_REPO / "BENCH_parallel.json"))
    args = ap.parse_args()
    _ensure_devices(args.mesh)  # before any jax import

    defaults = ((3, 6) if args.quick else (6, 12))
    report = bench(mesh_n=args.mesh, arch=args.arch,
                   requests=args.requests or defaults[0],
                   max_new=args.max_new or defaults[1],
                   slots=args.slots, page_size=args.page_size,
                   prefill_chunk=args.prefill_chunk, kv_dtype=args.kv_dtype)
    Path(args.out).write_text(
        json.dumps(report, indent=2, sort_keys=True) + "\n")

    m = report["mesh_engine"]
    print(f"wrote {args.out} (backend={report['backend']}, mesh={report['mesh']}, "
          f"outputs_identical={report['outputs_identical']})")
    print(f"  kv pool:  {report['single']['kv_pool_bytes']}B logical -> "
          f"{m['kv_pool_bytes_per_device']}B/device "
          f"({report['kv_bytes_reduction']:.2f}x)")
    print(f"  weights:  {report['single']['weight_bytes']}B -> "
          f"{m['weight_bytes_per_device']}B/device "
          f"({report['weight_bytes_reduction']:.2f}x)")
    print(f"  decode tok/s: single={report['single']['decode_tps']:.1f}  "
          f"mesh={m['decode_tps']:.1f} (simulated devices share one host)")

    failed = False
    if not report["outputs_identical"]:
        print("FAIL: mesh engine emitted different greedy tokens than the "
              "1-device engine", file=sys.stderr)
        failed = True
    if report["kv_bytes_reduction"] < MIN_KV_REDUCTION:
        print(f"FAIL: per-device KV pool reduction "
              f"{report['kv_bytes_reduction']:.2f}x < "
              f"{MIN_KV_REDUCTION}x gate", file=sys.stderr)
        failed = True
    if report["weight_bytes_reduction"] < MIN_WEIGHT_REDUCTION:
        print(f"FAIL: per-device weight reduction "
              f"{report['weight_bytes_reduction']:.2f}x < "
              f"{MIN_WEIGHT_REDUCTION}x gate", file=sys.stderr)
        failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
