"""Calibration of the Level-A simulator constants (run once; results frozen
into ``repro.core.calibration``).

Stage 1 fits the -O0 codegen knobs (spill/mv/extra-alu) to the LeNet/RV64F
instruction and mem-type counts of paper Table III.
Stage 2 fits the microarchitectural latency knobs to the LeNet IPC triplet
(one core, three ISAs — the same hardware constants must explain all three).
Stage 3 fits the L1I fetch granularity to the LeNet L1-access triplet.

ResNet-20 and MobileNet-V1 (30 metric cells) are *predictions* — never
touched by the fit.
"""
from __future__ import annotations

import itertools
import sys

from repro.core.isa import Isa
from repro.core.pipeline import PipelineParams
from repro.core.program import CodegenParams
from repro.core.simulate import simulate_model

PAPER_LENET = {
    Isa.RV64F: dict(ic=44_310_154, mem=19_288_578, ipc=0.666, l1=23_071_838, rt=0.066),
    Isa.BASELINE: dict(ic=35_792_547, mem=16_043_778, ipc=0.740, l1=19_841_884, rt=0.048),
    Isa.RV64R: dict(ic=27_010_675, mem=12_045_594, ipc=0.847, l1=15_449_482, rt=0.032),
}


def relerr(a: float, b: float) -> float:
    return abs(a - b) / b


def stage1() -> CodegenParams:
    best, best_err = None, 1e9
    for spills, mv, extra in itertools.product(range(0, 4), range(0, 6), range(0, 24, 2)):
        cg = CodegenParams(spills_per_ref=spills, mv_per_ref=mv, extra_alu_per_mac=extra)
        m = simulate_model("lenet", Isa.RV64F, codegen=cg, pipeline=PipelineParams())
        err = relerr(m.instructions, PAPER_LENET[Isa.RV64F]["ic"]) + relerr(
            m.mem_instrs, PAPER_LENET[Isa.RV64F]["mem"]
        )
        if err < best_err:
            best, best_err = cg, err
    print(f"[stage1] {best} err={best_err:.4f}")
    return best


def stage2(cg: CodegenParams) -> PipelineParams:
    best, best_err = None, 1e9
    for lu, imul, idiv, fp, bp, jp in itertools.product(
        (1, 2), (2, 3, 4), (4, 8, 12, 16, 20, 24), (4, 8, 12, 16), (2, 3), (1, 2)
    ):
        pp = PipelineParams(
            load_use_penalty=lu, int_mul_latency=imul, int_div_latency=idiv,
            fp_latency=fp, branch_penalty=bp, jump_penalty=jp,
        )
        err = 0.0
        for isa in (Isa.RV64F, Isa.BASELINE, Isa.RV64R):
            m = simulate_model("lenet", isa, codegen=cg, pipeline=pp)
            err += relerr(m.ipc, PAPER_LENET[isa]["ipc"]) ** 2
        if err < best_err:
            best, best_err = pp, err
    print(f"[stage2] lu={best.load_use_penalty} imul={best.int_mul_latency} "
          f"idiv={best.int_div_latency} fp={best.fp_latency} "
          f"bp={best.branch_penalty} jp={best.jump_penalty} err={best_err:.5f}")
    return best


def stage3(cg: CodegenParams, pp: PipelineParams) -> PipelineParams:
    best, best_err = None, 1e9
    from dataclasses import replace
    for fetch, ibytes in itertools.product((24, 32, 40, 48, 64, 96, 128), (3, 4)):
        cand = replace(pp, fetch_bytes=fetch, instr_bytes=ibytes)
        err = 0.0
        for isa in PAPER_LENET:
            m = simulate_model("lenet", isa, codegen=cg, pipeline=cand)
            err += relerr(m.l1_accesses, PAPER_LENET[isa]["l1"]) ** 2
        if err < best_err:
            best, best_err = cand, err
    print(f"[stage3] fetch={best.fetch_bytes} instr_bytes={best.instr_bytes} err={best_err:.5f}")
    return best


def main() -> None:
    cg = stage1()
    pp = stage2(cg)
    pp = stage3(cg, pp)
    print("\nFinal constants:")
    print("CODEGEN =", cg)
    print("PIPELINE =", pp)
    print("\nLeNet check (ours vs paper):")
    for isa in PAPER_LENET:
        m = simulate_model("lenet", isa, codegen=cg, pipeline=pp)
        p = PAPER_LENET[isa]
        print(f"  {isa.pretty:9s} IC {m.instructions/1e6:7.2f}M/{p['ic']/1e6:7.2f}M  "
              f"mem {m.mem_instrs/1e6:6.2f}M/{p['mem']/1e6:6.2f}M  "
              f"IPC {m.ipc:.3f}/{p['ipc']:.3f}  L1 {m.l1_accesses/1e6:6.2f}M/{p['l1']/1e6:6.2f}M  "
              f"rt {m.runtime_s:.4f}/{p['rt']:.3f}")


if __name__ == "__main__":
    main()
