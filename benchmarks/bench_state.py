"""Recurrent-family serving benchmark: paged state cache vs slot engine.

Drives one request trace per recurrent-state family — rwkv6 (linear
attention), mamba2 (SSD), zamba2 (hybrid: attention pages + mamba state
slots in one cache) — through the contiguous slot engine and through
``PagedServeEngine`` backed by ``repro.serve.state_cache``, and writes
``BENCH_state.json`` (schema in benchmarks/README.md).  Exits non-zero
unless, for every family, the paged engine's greedy outputs are
**token-identical** to the slot engine's and int8 state storage cuts
state-pool bytes by **>= 1.5x** (the CI gate).

Per family the report carries:

* ``slot`` / ``paged`` — wall-clock + phase-local throughput for both
  engines (the paged side reports prefill/decode tok/s from
  ``EngineMetrics``),
* ``tokens_identical`` — the greedy identity gate,
* ``state_pool_bytes_fp32`` vs ``state_pool_bytes_int8`` — the state-pool
  footprint at both storage dtypes (``state_dtype="int8"`` stores the
  large wkv/ssm running-reduction leaves int8 + per-head scales).  int8
  state is **lossy across steps** (re-quantized every token, unlike int8
  KV), so the int8 run's identity is reported (``tokens_identical_int8``)
  but deliberately **not** gated.

    PYTHONPATH=src python benchmarks/bench_state.py --quick
"""
import argparse
import datetime
import json
import sys
import time
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
for _p in (str(_REPO / "src"), str(_REPO / "benchmarks")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from _serve_common import request_trace as _trace  # noqa: E402
from _serve_common import warm_engine  # noqa: E402

SCHEMA_VERSION = 1

#: int8 state storage must cut state-pool bytes by at least this much
#: (wkv/ssm go 4x; the conv-window / token-shift leaves stay native)
MIN_STATE_BYTES_REDUCTION = 1.5

#: the recurrent-state families the StateCache serves (ssm / mamba /
#: hybrid); zamba2 is the mixed case — KV pages AND state slots
FAMILY_ARCHS = ("rwkv6-3b", "mamba2-2.7b", "zamba2-1.2b")


def _state_pool_bytes(engine) -> int:
    from repro.models.paged_state import STATE_POOL_KEYS
    return sum(int(a.size) * a.dtype.itemsize
               for k, a in engine.cache.items() if k in STATE_POOL_KEYS)


def _run_slot(bundle, params, pctx, reqs, *, slots, max_seq):
    from repro.serve import ServeEngine
    eng = ServeEngine(bundle, params, pctx, slots=slots, max_seq=max_seq)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in reqs)
    return {"elapsed_s": round(dt, 4), "total_tokens": total,
            "tokens_per_s": round(total / max(dt, 1e-9), 2),
            "outputs": [r.output for r in reqs]}


def _run_paged(bundle, params, pctx, reqs, *, slots, page_size,
               prefill_chunk, state_dtype):
    from repro.serve import PagedServeEngine
    eng = PagedServeEngine(bundle, params, pctx, slots=slots,
                           page_size=page_size, prefill_chunk=prefill_chunk,
                           state_dtype=state_dtype)
    warm_engine(eng, prompt_len=prefill_chunk + 1)
    for r in reqs:
        eng.submit(r)
    m = eng.run_until_drained()
    out = {k: m.summary()[k] for k in
           ("requests_done", "prefill_tokens", "decode_tokens",
            "prefill_tps", "decode_tps")}
    out["state_pool_bytes"] = _state_pool_bytes(eng)
    out["cache_pool_bytes"] = eng.kv_pool_bytes()
    out["state_pool_slots"] = eng.state.pool_slots
    out["outputs"] = [r.output for r in reqs]
    assert eng.state.used_slots == 0 and eng.kv.used_pages == 0, \
        "drained engine must leak no state slots or KV pages"
    return out


def bench_family(arch, pctx, *, requests, prompt_len, max_new, slots,
                 page_size, prefill_chunk):
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))

    run_trace = lambda: _trace(requests, prompt_len, max_new)
    slot = _run_slot(bundle, params, pctx, run_trace(), slots=slots,
                     max_seq=max(128, prompt_len + max_new + 2))
    paged = _run_paged(bundle, params, pctx, run_trace(), slots=slots,
                       page_size=page_size, prefill_chunk=prefill_chunk,
                       state_dtype="float32")
    int8 = _run_paged(bundle, params, pctx, run_trace(), slots=slots,
                      page_size=page_size, prefill_chunk=prefill_chunk,
                      state_dtype="int8")
    ref = slot.pop("outputs")
    return {
        "family": cfg.family,
        "slot": slot,
        "paged": paged,
        "tokens_identical": paged.pop("outputs") == ref,
        "state_pool_bytes_fp32": paged["state_pool_bytes"],
        "state_pool_bytes_int8": int8["state_pool_bytes"],
        "state_bytes_reduction": round(
            paged["state_pool_bytes"] / max(int8["state_pool_bytes"], 1), 3),
        # int8 state is lossy across steps: reported, never gated
        "tokens_identical_int8": int8.pop("outputs") == ref,
        "decode_tps_int8": int8["decode_tps"],
    }


def bench(*, quick: bool, requests: int, prompt_len: int, max_new: int,
          slots: int, page_size: int, prefill_chunk: int):
    import jax

    from repro.parallel.sharding import ParallelContext

    pctx = ParallelContext(None)
    families = {arch: bench_family(
        arch, pctx, requests=requests, prompt_len=prompt_len,
        max_new=max_new, slots=slots, page_size=page_size,
        prefill_chunk=prefill_chunk) for arch in FAMILY_ARCHS}

    return {
        "schema_version": SCHEMA_VERSION,
        "generated_at": datetime.datetime.now().isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "mode": "quick" if quick else "full",
        "workload": {"requests": requests, "prompt_len": prompt_len,
                     "max_new": max_new, "slots": slots,
                     "page_size": page_size, "prefill_chunk": prefill_chunk},
        "families": families,
        "outputs_identical": all(f["tokens_identical"]
                                 for f in families.values()),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized trace (what the workflow runs)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--out", default=str(_REPO / "BENCH_state.json"))
    args = ap.parse_args()

    defaults = ((3, 24, 6) if args.quick else (6, 48, 12))
    requests = args.requests or defaults[0]
    prompt_len = args.prompt_len or defaults[1]
    max_new = args.max_new or defaults[2]

    report = bench(quick=args.quick, requests=requests,
                   prompt_len=prompt_len, max_new=max_new, slots=args.slots,
                   page_size=args.page_size,
                   prefill_chunk=min(args.prefill_chunk, prompt_len))
    Path(args.out).write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")

    print(f"wrote {args.out} (backend={report['backend']})")
    ok = True
    for arch, f in report["families"].items():
        print(f"  {arch} ({f['family']}): paged identical="
              f"{f['tokens_identical']} decode {f['paged']['decode_tps']} "
              f"tok/s (slot {f['slot']['tokens_per_s']} tok/s wall); state "
              f"pool {f['state_pool_bytes_fp32']}B fp32 -> "
              f"{f['state_pool_bytes_int8']}B int8 "
              f"({f['state_bytes_reduction']:.2f}x; int8 identical="
              f"{f['tokens_identical_int8']}, ungated)")
        ok &= f["tokens_identical"]
        ok &= f["state_bytes_reduction"] >= MIN_STATE_BYTES_REDUCTION
    if not ok:
        print(f"FAIL: every family must be token-identical to the slot "
              f"engine and int8 state must cut state-pool bytes >= "
              f"{MIN_STATE_BYTES_REDUCTION}x", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
