"""Paper Fig. 1: inner-loop instruction mix per ISA (main instructions +
memory breakdown) from the Level-A codegen."""
import time

from repro.core import calibration
from repro.core.isa import Isa, Kind
from repro.core.program import mac_body, rfsmac_block


def run(csv=False):
    rows = []
    t0 = time.time()
    if not csv:
        print(f"{'ISA':9s} {'total':>6s} {'flw':>4s} {'fsw':>4s} "
              f"{'int-ld':>7s} {'int-st':>7s} {'fp-arith':>9s} {'div':>4s}")
    for isa in Isa:
        body = mac_body(isa, calibration.CODEGEN)
        counts = {
            "flw": sum(1 for i in body if i.kind == Kind.FLW),
            "fsw": sum(1 for i in body if i.kind == Kind.FSW),
            "ild": sum(1 for i in body if i.kind == Kind.LOAD),
            "ist": sum(1 for i in body if i.kind == Kind.STORE),
            "fp": sum(1 for i in body if i.kind.is_arith_fp),
            "div": sum(1 for i in body if i.kind == Kind.DIV),
        }
        if not csv:
            print(f"{isa.pretty:9s} {len(body):6d} {counts['flw']:4d} "
                  f"{counts['fsw']:4d} {counts['ild']:7d} {counts['ist']:7d} "
                  f"{counts['fp']:9d} {counts['div']:4d}")
        rows.append(
            f"fig1.{isa.value},{(time.time()-t0)*1e6/3:.0f},"
            f"total={len(body)};flw={counts['flw']};fsw={counts['fsw']};"
            f"div={counts['div']}"
        )
    if not csv:
        epi = rfsmac_block(calibration.CODEGEN)
        print(f"RV64R per-output epilogue: {len(epi)} instrs "
              f"(rfsmac + fsw + address)")
    return rows
