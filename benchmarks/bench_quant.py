"""Quantized-inference benchmark: int8 weights / int8 KV vs the float path.

Three sections, one JSON report (``BENCH_quant.json``, schema in
benchmarks/README.md):

* ``kernel``  — ``quant_matmul`` (int8 x int8, int32 APR) vs ``apr_matmul``
  (fp32 APR) on the same GEMM: us/call, analytic weight bytes streamed, and
  max-abs-err of the quantized result against the fp32 product,
* ``weights`` — byte accounting for the smoke model's int8-weight variant
  (``repro.quant.quantize_params``): fp32 / bf16 / int8+scales footprints of
  the streamed matmul weights — the bytes a decode step moves per token,
* ``engine``  — the same request trace through ``PagedServeEngine`` with
  (a) float weights, (b) int8 weights, (c) int8 weights + int8 paged KV:
  decode/prefill tok/s, KV pool bytes, **greedy top-1 token identity**
  against the float path, and max-abs-err of the int8-weight logits.

Off-TPU everything runs in Pallas-interpret / XLA-CPU mode, so times are a
correctness-path proxy (the ``backend`` field records this); byte counts
are analytic and backend-independent.

    PYTHONPATH=src python benchmarks/bench_quant.py --quick
"""
import argparse
import datetime
import json
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
for _p in (str(_REPO / "src"), str(_REPO / "benchmarks")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from _serve_common import request_trace as _trace  # noqa: E402
from _serve_common import warm_engine  # noqa: E402

SCHEMA_VERSION = 1

SHAPES = {"quick": {"m": 64, "k": 128, "n": 64},
          "full": {"m": 256, "k": 2048, "n": 512}}


def bench_kernel(shape, iters: int):
    import jax
    import jax.numpy as jnp

    from repro.bench.autotune import time_callable
    from repro.kernels.apr_matmul import ops as fp_ops
    from repro.kernels.quant_matmul import ops as q_ops
    from repro.kernels.quant_matmul.ref import quant_matmul_ref

    m, k, n = shape["m"], shape["k"], shape["n"]
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(ky, (k, n), jnp.float32)
    w_q, w_scale = q_ops.quantize_weights(w)

    fp = fp_ops.apr_matmul(x, w)
    qt = q_ops.quant_matmul(x, w_q, w_scale)
    err_fp = float(jnp.max(jnp.abs(qt - fp)))
    err_oracle = float(jnp.max(jnp.abs(qt - quant_matmul_ref(x, w_q, w_scale))))
    t_fp = time_callable(lambda: fp_ops.apr_matmul(x, w), iters=iters)
    t_q = time_callable(lambda: q_ops.quant_matmul(x, w_q, w_scale),
                        iters=iters)
    w_bytes_fp32 = k * n * 4
    w_bytes_int8 = k * n * 1 + n * 4          # payload + per-channel scales
    return {
        "shape": dict(shape),
        "us_apr_matmul_fp32": round(t_fp * 1e6, 2),
        "us_quant_matmul_int8": round(t_q * 1e6, 2),
        "weight_bytes_fp32": w_bytes_fp32,
        "weight_bytes_int8": w_bytes_int8,
        "weight_bytes_reduction": round(w_bytes_fp32 / w_bytes_int8, 3),
        "max_abs_err_vs_fp32": round(err_fp, 6),
        "max_abs_err_vs_oracle": round(err_oracle, 9),
    }


def _run_engine(bundle, params, pctx, reqs, *, slots, page_size,
                prefill_chunk, kv_dtype):
    from repro.serve import PagedServeEngine
    eng = PagedServeEngine(bundle, params, pctx, slots=slots,
                           page_size=page_size, prefill_chunk=prefill_chunk,
                           kv_dtype=kv_dtype)
    # warm the jit caches so the timed trace measures steady-state serving
    warm_engine(eng, prompt_len=prefill_chunk + 1)
    for r in reqs:
        eng.submit(r)
    m = eng.run_until_drained()
    out = {k: m.summary()[k] for k in
           ("requests_done", "prefill_tokens", "decode_tokens",
            "prefill_tps", "decode_tps")}
    out["kv_pool_bytes"] = eng.kv_pool_bytes()
    return out, [r.output for r in reqs]


def bench(*, arch: str, quick: bool, requests: int, prompt_len: int,
          max_new: int, slots: int, page_size: int, prefill_chunk: int,
          iters: int):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model, lm
    from repro.parallel.sharding import ParallelContext
    from repro.quant import weight_bytes
    from repro.serve.paged_cache import kv_token_bytes

    cfg = get_config(arch, smoke=True)
    if cfg.family not in ("dense", "moe", "vlm"):
        # the engine section needs a paged KV cache and the logits section
        # drives lm_forward directly; audio has int8 weights but neither.
        raise SystemExit(
            f"bench_quant needs a dense/moe/vlm arch (paged-KV + lm "
            f"forward); {arch!r} is family {cfg.family!r}")
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    qparams = bundle.quantize_params(params)
    pctx = ParallelContext(None)

    # -- weights: the decode-step bandwidth story -------------------------
    wb = weight_bytes(qparams)
    weights = {
        "n_quantized_tensors": wb["n_quantized"],
        "n_passthrough_tensors": wb["n_passthrough"],
        "streamed_bytes_fp32": wb["bytes_fp32"],
        "streamed_bytes_bf16": wb["bytes_bf16"],
        "streamed_bytes_int8": wb["bytes_actual"],
        "reduction_vs_fp32": round(wb["bytes_fp32"] / wb["bytes_actual"], 3),
        "reduction_vs_bf16": round(wb["bytes_bf16"] / wb["bytes_actual"], 3),
        "kv_bytes_per_token_bf16": kv_token_bytes(
            cfg.num_kv_heads, cfg.resolved_head_dim, "bfloat16"),
        "kv_bytes_per_token_int8": kv_token_bytes(
            cfg.num_kv_heads, cfg.resolved_head_dim, "int8"),
    }

    # -- logits error (teacher-forced forward, float vs int8 weights) -----
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, prompt_len),
                              0, cfg.vocab_size)
    lf = lm.lm_forward(params, cfg, pctx, toks)
    lq = lm.lm_forward(qparams, cfg, pctx, toks)
    logits_err = float(jnp.max(jnp.abs(lf.astype(jnp.float32)
                                       - lq.astype(jnp.float32))))

    # -- engine: same trace, three precision configurations ---------------
    run = lambda ps, kv: _run_engine(
        bundle, ps, pctx, _trace(requests, prompt_len, max_new),
        slots=slots, page_size=page_size, prefill_chunk=prefill_chunk,
        kv_dtype=kv)
    eng_fp, out_fp = run(params, "bfloat16")
    eng_q, out_q = run(qparams, "bfloat16")
    eng_qkv, out_qkv = run(qparams, "int8")

    return {
        "schema_version": SCHEMA_VERSION,
        "generated_at": datetime.datetime.now().isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "mode": "quick" if quick else "full",
        "arch": arch,
        "workload": {"requests": requests, "prompt_len": prompt_len,
                     "max_new": max_new, "slots": slots,
                     "page_size": page_size, "prefill_chunk": prefill_chunk},
        "kernel": bench_kernel(SHAPES["quick" if quick else "full"], iters),
        "weights": weights,
        "logits_max_abs_err": round(logits_err, 6),
        "engine": {"float": eng_fp, "int8_weights": eng_q,
                   "int8_weights_int8_kv": eng_qkv},
        "tokens_identical_int8_weights": out_fp == out_q,
        "tokens_identical_int8_weights_int8_kv": out_fp == out_qkv,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized trace + small GEMM")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=str(_REPO / "BENCH_quant.json"))
    args = ap.parse_args()

    defaults = ((4, 24, 8) if args.quick else (8, 64, 16))
    requests = args.requests or defaults[0]
    prompt_len = args.prompt_len or defaults[1]
    max_new = args.max_new or defaults[2]

    report = bench(arch=args.arch, quick=args.quick, requests=requests,
                   prompt_len=prompt_len, max_new=max_new, slots=args.slots,
                   page_size=args.page_size,
                   prefill_chunk=min(args.prefill_chunk, prompt_len),
                   iters=args.iters)
    Path(args.out).write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")

    w, k = report["weights"], report["kernel"]
    print(f"wrote {args.out} (backend={report['backend']}, "
          f"arch={report['arch']})")
    print(f"  weight bytes/decode step: fp32={w['streamed_bytes_fp32']}  "
          f"int8={w['streamed_bytes_int8']}  "
          f"({w['reduction_vs_fp32']:.2f}x vs fp32, "
          f"{w['reduction_vs_bf16']:.2f}x vs bf16)")
    print(f"  quant_matmul: {k['us_quant_matmul_int8']}us vs apr_matmul "
          f"{k['us_apr_matmul_fp32']}us; max|err| vs fp32 "
          f"{k['max_abs_err_vs_fp32']}")
    print(f"  logits max|err| (int8 weights): {report['logits_max_abs_err']}")
    print(f"  greedy tokens identical: int8-weights="
          f"{report['tokens_identical_int8_weights']}  +int8-kv="
          f"{report['tokens_identical_int8_weights_int8_kv']}")
    ok = (report["tokens_identical_int8_weights"]
          and report["weights"]["reduction_vs_fp32"] >= 2.0)
    if not ok:
        print("FAIL: int8-weight path must emit identical greedy tokens and "
              "move >= 2x fewer weight bytes than fp32", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
