"""Graph-compiler benchmark: fused vs unfused execution + traffic plan.

Three sections, one JSON report (``BENCH_graph.json``, schema in
benchmarks/README.md):

* ``cnn``     — the paper's CNNs lowered by ``repro.graph.trace`` and run
  through the executor fused vs unfused: us/forward, node/cluster counts,
  planner intermediate-HBM-bytes before/after fusion, arena-reuse factor,
  and max-abs-err of both paths against the direct XLA forward,
* ``prefill`` — the smoke LM's chunked-prefill step (the paged serve
  contract at B=1, T=chunk) graph-compiled fused vs unfused: us/chunk and
  the same planner numbers.  This is the headline fused-vs-unfused
  latency the CI gate checks (>= 1.2x),
* ``decode``  — the batched T=1 decode tick graph-compiled fused vs
  unfused for the attention LM **and** one recurrent family (rwkv6,
  state gather/scatter through the fused clusters): intermediate-HBM
  bytes must drop for both (gated; no latency gate — a single tick is
  dispatch-dominated off-TPU).  The hybrid family is excluded by design:
  the engine rejects ``use_graph`` for it (FMA-contraction sensitivity
  at cluster boundaries),
* ``engine``  — the same request trace through ``PagedServeEngine`` with
  ``use_graph=False`` vs ``use_graph=True``: **greedy outputs must be
  token-identical** (gated) plus prefill/decode tok/s for context.
  ``engine_recurrent`` repeats the comparison on the rwkv6 engine, whose
  graph path compiles the decode tick too (same identity gate).

Unfused execution runs every primitive as its own compiled call — every
intermediate materializes, the graph-level HBM baseline.  Fused execution
runs the fusion-pass clusters as single compiled regions (the graph-level
APR).  Off-TPU both paths execute through XLA-CPU, so times are a
dispatch/materialization-boundary proxy (the ``backend`` field records
this); planner byte counts are analytic and backend-independent.

    PYTHONPATH=src python benchmarks/bench_graph.py --quick
"""
import argparse
import datetime
import json
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
for _p in (str(_REPO / "src"), str(_REPO / "benchmarks")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from _serve_common import request_trace as _trace  # noqa: E402
from _serve_common import warm_engine  # noqa: E402

SCHEMA_VERSION = 1
GATE_SPEEDUP = 1.2

#: the recurrent family the decode-tick section runs next to the
#: attention LM (the hybrid is excluded — the engine rejects use_graph
#: for it; see repro.graph.compiler.compile_decode_step)
RECURRENT_ARCH = "rwkv6-3b"


def _graph_stats(graph):
    from repro.graph import arena_plan, memory_report
    mem = memory_report(graph)
    arena = arena_plan(graph)
    s = graph.summary()
    return {
        "n_nodes": s["n_nodes"],
        "n_fused_clusters": s["n_fused"],
        "n_primitive_ops": s["n_primitive_ops"],
        "intermediate_hbm_bytes": mem.intermediate_bytes,
        "intermediate_hbm_traffic": mem.intermediate_traffic,
        "arena_bytes": arena.arena_bytes,
        "arena_naive_bytes": arena.naive_bytes,
        "arena_reuse_factor": round(arena.reuse_factor, 3),
    }


def bench_cnn(names, iters: int):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.bench.autotune import time_callable
    from repro.graph import GraphExecutor, run_passes, trace
    from repro.models.cnn import CNNS

    out = {}
    for name in names:
        spec = CNNS[name]
        params = spec["params"](jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2,) + spec["input"])
        fwd = lambda xx: spec["forward"](params, xx)
        ref = np.asarray(fwd(x))
        ex_u = GraphExecutor(trace(fwd, x, name=name))
        ex_f = GraphExecutor(run_passes(trace(fwd, x, name=name)))
        err_u = float(np.max(np.abs(np.asarray(ex_u(x)) - ref)))
        err_f = float(np.max(np.abs(np.asarray(ex_f(x)) - ref)))
        t_u = time_callable(lambda: ex_u(x), iters=iters)
        t_f = time_callable(lambda: ex_f(x), iters=iters)
        su, sf = _graph_stats(ex_u.graph), _graph_stats(ex_f.graph)
        out[name] = {
            "us_unfused": round(t_u * 1e6, 1),
            "us_fused": round(t_f * 1e6, 1),
            "fused_speedup": round(t_u / t_f, 3),
            "max_abs_err_unfused": round(err_u, 6),
            "max_abs_err_fused": round(err_f, 6),
            "unfused": su,
            "fused": sf,
            "intermediate_bytes_reduction": round(
                su["intermediate_hbm_bytes"]
                / max(sf["intermediate_hbm_bytes"], 1), 3),
        }
    return out


def bench_prefill(bundle, params, pctx, *, chunk: int, page_size: int,
                  iters: int):
    import jax.numpy as jnp
    import numpy as np

    from repro.bench.autotune import time_callable
    from repro.graph.compiler import compile_prefill_step

    width = max(256 // page_size, 1)          # engine-default table width
    pool_pages = 2 * width + 1
    cache = bundle.init_paged_cache(pool_pages, page_size)
    build = lambda fused: compile_prefill_step(
        bundle, params, cache, chunk=chunk, table_width=width, pctx=pctx,
        fused=fused)
    fused, unfused = build(True), build(False)
    toks = jnp.ones((1, chunk), jnp.int32)
    lengths = jnp.zeros((1,), jnp.int32)
    counts = jnp.full((1,), chunk, jnp.int32)
    bt = jnp.arange(1, width + 1, dtype=jnp.int32)[None]
    args = (params, cache, toks, lengths, counts, bt)
    lf = np.asarray(fused(*args)[0], np.float32)
    lu = np.asarray(unfused(*args)[0], np.float32)
    # this section carries the CI gate: extra reps + warmup so a single
    # scheduler hiccup on a shared runner can't flip the >= 1.2x check
    gate_iters = max(iters, 5)
    t_f = time_callable(lambda: fused(*args)[0], iters=gate_iters, warmup=2)
    t_u = time_callable(lambda: unfused(*args)[0], iters=gate_iters, warmup=2)
    su = _graph_stats(unfused.executor.graph)
    sf = _graph_stats(fused.executor.graph)
    return {
        "chunk": chunk,
        "us_unfused": round(t_u * 1e6, 1),
        "us_fused": round(t_f * 1e6, 1),
        "fused_speedup": round(t_u / t_f, 3),
        "logits_max_abs_err": round(float(np.max(np.abs(lf - lu))), 6),
        "unfused": su,
        "fused": sf,
        "intermediate_bytes_reduction": round(
            su["intermediate_hbm_bytes"]
            / max(sf["intermediate_hbm_bytes"], 1), 3),
    }


def bench_decode(arch, pctx, *, slots: int, page_size: int, iters: int,
                 bundle, params):
    """The batched T=1 decode tick graph-compiled fused vs unfused — the
    serve-loop sibling of :func:`bench_prefill`, at the engine's decode
    geometry (B=slots).  State families get the combined block table (KV
    page columns + state read col + one write col) and a state pool."""
    import jax.numpy as jnp
    import numpy as np

    from repro.bench.autotune import time_callable
    from repro.graph.compiler import compile_decode_step
    from repro.serve.state_cache import StateCache

    width = max(256 // page_size, 1)          # engine-default table width
    state = StateCache(slots=slots) if bundle.supports_paged_state else None
    table_width = width + (2 if state else 0)  # + read col + T=1 write col
    cache = bundle.init_paged_cache(
        slots + 2, page_size,
        state_slots=(state.pool_slots if state else 0))
    build = lambda fused: compile_decode_step(
        bundle, params, cache, slots=slots, table_width=table_width,
        pctx=pctx, fused=fused)
    fused, unfused = build(True), build(False)
    # one mid-page token per slot: page i+1, position page_size // 2
    toks = jnp.ones((slots, 1), jnp.int32)
    lengths = jnp.full((slots,), page_size // 2, jnp.int32)
    counts = jnp.ones((slots,), jnp.int32)
    kv = np.zeros((slots, width), np.int32)
    kv[:, 0] = 1 + np.arange(slots)
    if state is not None:
        ids = np.array([[state.alloc(s)] for s in range(slots)], np.int32)
        bt = jnp.asarray(np.concatenate([kv, ids, ids], axis=1))
    else:
        bt = jnp.asarray(kv)
    args = (params, cache, toks, lengths, counts, bt)
    lf = np.asarray(fused(*args)[0], np.float32)
    lu = np.asarray(unfused(*args)[0], np.float32)
    t_f = time_callable(lambda: fused(*args)[0], iters=iters, warmup=1)
    t_u = time_callable(lambda: unfused(*args)[0], iters=iters, warmup=1)
    su = _graph_stats(unfused.executor.graph)
    sf = _graph_stats(fused.executor.graph)
    return {
        "slots": slots,
        "us_unfused": round(t_u * 1e6, 1),
        "us_fused": round(t_f * 1e6, 1),
        "fused_speedup": round(t_u / t_f, 3),
        "logits_max_abs_err": round(float(np.max(np.abs(lf - lu))), 6),
        "unfused": su,
        "fused": sf,
        "intermediate_bytes_reduction": round(
            su["intermediate_hbm_bytes"]
            / max(sf["intermediate_hbm_bytes"], 1), 3),
    }


def _run_engine(bundle, params, pctx, reqs, *, slots, page_size,
                prefill_chunk, use_graph):
    from repro.serve import PagedServeEngine
    eng = PagedServeEngine(bundle, params, pctx, slots=slots,
                           page_size=page_size, prefill_chunk=prefill_chunk,
                           use_graph=use_graph)
    warm_engine(eng, prompt_len=prefill_chunk + 1)
    for r in reqs:
        eng.submit(r)
    m = eng.run_until_drained()
    out = {k: m.summary()[k] for k in
           ("requests_done", "prefill_tokens", "decode_tokens",
            "prefill_tps", "decode_tps")}
    return out, [r.output for r in reqs]


def bench(*, arch: str, quick: bool, requests: int, prompt_len: int,
          max_new: int, slots: int, page_size: int, prefill_chunk: int,
          iters: int):
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel.sharding import ParallelContext

    cfg = get_config(arch, smoke=True)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit(
            f"bench_graph needs a dense/moe/vlm arch (paged prefill is the "
            f"graph-compiled step); {arch!r} is family {cfg.family!r}")
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    pctx = ParallelContext(None)

    cnn_names = ["lenet"] if quick else ["lenet", "resnet20"]
    run = lambda g: _run_engine(
        bundle, params, pctx, _trace(requests, prompt_len, max_new),
        slots=slots, page_size=page_size, prefill_chunk=prefill_chunk,
        use_graph=g)
    eng_plain, out_plain = run(False)
    eng_graph, out_graph = run(True)

    # T=1 decode tick: the attention LM (this bench's arch) plus one
    # recurrent family — rwkv6, whose graph decode runs the state
    # gather/scatter through the fused clusters.  The hybrid family is
    # deliberately absent: PagedServeEngine rejects use_graph for it
    # (FMA-contraction sensitivity at cluster boundaries; see
    # repro.graph.compiler.compile_decode_step).
    r_bundle = build_model(get_config(RECURRENT_ARCH, smoke=True))
    r_params = r_bundle.init_params(jax.random.PRNGKey(0))
    decode = {
        arch: bench_decode(arch, pctx, slots=slots, page_size=page_size,
                           iters=iters, bundle=bundle, params=params),
        RECURRENT_ARCH: bench_decode(
            RECURRENT_ARCH, pctx, slots=slots, page_size=page_size,
            iters=iters, bundle=r_bundle, params=r_params)}
    run_r = lambda g: _run_engine(
        r_bundle, r_params, pctx, _trace(requests, prompt_len, max_new),
        slots=slots, page_size=page_size, prefill_chunk=prefill_chunk,
        use_graph=g)
    reng_plain, rout_plain = run_r(False)
    reng_graph, rout_graph = run_r(True)

    return {
        "schema_version": SCHEMA_VERSION,
        "generated_at": datetime.datetime.now().isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "mode": "quick" if quick else "full",
        "arch": arch,
        "workload": {"requests": requests, "prompt_len": prompt_len,
                     "max_new": max_new, "slots": slots,
                     "page_size": page_size, "prefill_chunk": prefill_chunk},
        "cnn": bench_cnn(cnn_names, iters),
        "prefill": bench_prefill(bundle, params, pctx, chunk=prefill_chunk,
                                 page_size=page_size, iters=iters),
        "decode": decode,
        "engine": {"jit": eng_plain, "graph": eng_graph},
        "engine_recurrent": {"arch": RECURRENT_ARCH, "jit": reng_plain,
                             "graph": reng_graph},
        "tokens_identical_graph_engine": out_plain == out_graph,
        "tokens_identical_graph_engine_recurrent": rout_plain == rout_graph,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: LeNet only + small trace")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=str(_REPO / "BENCH_graph.json"))
    args = ap.parse_args()

    defaults = ((3, 24, 6) if args.quick else (6, 48, 12))
    requests = args.requests or defaults[0]
    prompt_len = args.prompt_len or defaults[1]
    max_new = args.max_new or defaults[2]

    report = bench(arch=args.arch, quick=args.quick, requests=requests,
                   prompt_len=prompt_len, max_new=max_new, slots=args.slots,
                   page_size=args.page_size,
                   prefill_chunk=min(args.prefill_chunk, prompt_len),
                   iters=args.iters)
    Path(args.out).write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")

    p = report["prefill"]
    print(f"wrote {args.out} (backend={report['backend']}, "
          f"arch={report['arch']})")
    print(f"  prefill chunk (T={p['chunk']}): fused {p['us_fused']}us vs "
          f"unfused {p['us_unfused']}us -> {p['fused_speedup']:.2f}x; "
          f"intermediate HBM bytes {p['unfused']['intermediate_hbm_bytes']}"
          f" -> {p['fused']['intermediate_hbm_bytes']} "
          f"({p['intermediate_bytes_reduction']:.2f}x)")
    for name, c in report["cnn"].items():
        print(f"  {name}: fused {c['us_fused']}us vs unfused "
              f"{c['us_unfused']}us -> {c['fused_speedup']:.2f}x; "
              f"bytes {c['intermediate_bytes_reduction']:.2f}x; "
              f"arena reuse {c['unfused']['arena_reuse_factor']:.2f}x")
    for name, d in report["decode"].items():
        print(f"  decode tick ({name}, B={d['slots']}): fused "
              f"{d['us_fused']}us vs unfused {d['us_unfused']}us -> "
              f"{d['fused_speedup']:.2f}x; intermediate HBM bytes "
              f"{d['unfused']['intermediate_hbm_bytes']} -> "
              f"{d['fused']['intermediate_hbm_bytes']} "
              f"({d['intermediate_bytes_reduction']:.2f}x)")
    print(f"  graph-engine greedy tokens identical: "
          f"{report['tokens_identical_graph_engine']} (attention), "
          f"{report['tokens_identical_graph_engine_recurrent']} "
          f"({report['engine_recurrent']['arch']})")
    ok = (report["tokens_identical_graph_engine"]
          and report["tokens_identical_graph_engine_recurrent"]
          and p["fused_speedup"] >= GATE_SPEEDUP
          and p["intermediate_bytes_reduction"] > 1.0
          and all(d["intermediate_bytes_reduction"] > 1.0
                  for d in report["decode"].values()))
    if not ok:
        print(f"FAIL: graph prefill must be >= {GATE_SPEEDUP}x faster fused "
              "than unfused, fusion must cut intermediate HBM bytes on the "
              "prefill chunk and every decode tick, and both graph engines "
              "(attention + recurrent) must emit identical greedy tokens",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
