"""Benchmark harness: one module per paper table/figure + the TPU-level
analogues.  Prints ``name,us_per_call,derived`` CSV lines (plus readable
tables to stderr-adjacent stdout sections when run directly).

    PYTHONPATH=src python -m benchmarks.run [--csv-only]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv-only", action="store_true")
    args, _ = ap.parse_known_args()
    csv = args.csv_only

    from . import (table3, fig1_mix, table4_cost, kernel_traffic,
                   roofline_table, perf_report, bench_kernels)

    all_rows = []
    for name, mod in [("Table III (paper)", table3),
                      ("Fig. 1 instruction mix", fig1_mix),
                      ("Table IV cost analogue", table4_cost),
                      ("Kernel traffic (APR vs HBM residency)", kernel_traffic),
                      ("Roofline (dry-run)", roofline_table),
                      ("Perf hillclimb (baseline vs variants)", perf_report),
                      ("Kernel autotune sweep (repro.bench, quick)",
                       bench_kernels)]:
        if not csv:
            print(f"\n===== {name} =====")
        all_rows += mod.run(csv=csv)

    if not csv:
        print("\n===== CSV =====")
    print("name,us_per_call,derived")
    for r in all_rows:
        print(r)


if __name__ == "__main__":
    main()
