"""§Perf comparison: baseline vs variant roofline terms per hillclimbed
cell, from dryrun_results.json entries written by
``python -m repro.launch.dryrun --variant <v>``."""
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")

CELLS = [
    ("llama3-8b", "train_4k"),
    ("arctic-480b", "train_4k"),
    ("rwkv6-3b", "prefill_32k"),
]


def run(csv=False):
    rows = []
    try:
        with open(RESULTS) as f:
            results = json.load(f)
    except FileNotFoundError:
        return rows
    for arch, shape in CELLS:
        base_key = f"{arch}|{shape}|single"
        base = results.get(base_key)
        if not base or base.get("status") != "ok":
            continue
        variants = {k.split("|")[-1]: v for k, v in results.items()
                    if k.startswith(base_key + "|") and v.get("status") == "ok"}
        if not csv:
            print(f"\n{arch} x {shape}  (dominant={base['dominant']})")
            print(f"  {'variant':22s} {'t_comp':>9s} {'t_mem':>9s} "
                  f"{'t_coll':>9s} {'bound':>9s} {'vs base':>8s}")
        b_bound = max(base["t_compute_s"], base["t_memory_s"], base["t_collective_s"])
        for name, v in [("baseline", base)] + sorted(variants.items()):
            bound = max(v["t_compute_s"], v["t_memory_s"], v["t_collective_s"])
            if not csv:
                print(f"  {name:22s} {v['t_compute_s']:9.3f} "
                      f"{v['t_memory_s']:9.3f} {v['t_collective_s']:9.3f} "
                      f"{bound:9.3f} {b_bound/bound:7.2f}x")
            rows.append(f"perf.{arch}.{shape}.{name},{bound*1e6:.0f},"
                        f"speedup={b_bound/bound:.3f}")
    return rows


if __name__ == "__main__":
    run()
